"""Pallas coupled-Milstein path-simulation kernel.

The sequential hot loop of the workload: given Brownian increments
``dW[B, n]`` on one grid, produce the asset path ``S[B, n+1]`` under the
Milstein scheme (strong order 1 — the standard MLMC solver, Giles 2008).

The MLMC *coupling* is expressed by :func:`coupled_milstein_paths`, which
simulates the fine grid from ``dW`` and the coarse grid from the pairwise-
summed increments of the *same* ``dW`` — both via this kernel, so the two
levels share one Brownian path.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over batch tiles of
``BATCH_TILE`` paths; per tile the whole path (``BATCH_TILE x (n+1)``
floats, <=129 KiB at n=256/tile=128) lives in VMEM for the duration of the
time loop, which is the part a GPU version would keep in registers/shared
memory per threadblock. The time loop is a ``fori_loop`` inside the kernel:
sequential in time (that *is* the paper's parallel-complexity bottleneck,
O(2^{c l}) depth per level), parallel across paths.

In deep hedging the path S does not depend on the trainable parameters, so
this kernel needs no VJP — the model calls it under ``stop_gradient``
semantics (it only ever receives the non-differentiable ``dw`` argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..problem import HedgingProblem

BATCH_TILE = 128
INTERPRET = True


def _milstein_kernel(dw_ref, s_ref, *, mu, sigma, s0, dt, n_steps, geometric):
    """One batch tile: sequential Milstein time loop, whole path in VMEM."""
    s_ref[:, 0] = jnp.full((dw_ref.shape[0],), s0, dtype=s_ref.dtype)

    def body(t, _):
        s = s_ref[:, t]
        dw = dw_ref[:, t]
        drift = mu * s if geometric else jnp.full_like(s, mu)
        s_next = (
            s
            + drift * dt
            + sigma * s * dw
            + 0.5 * sigma * sigma * s * (dw * dw - dt)
        )
        s_ref[:, t + 1] = s_next
        return 0

    jax.lax.fori_loop(0, n_steps, body, 0)


def milstein_paths(dw: jax.Array, problem: HedgingProblem, n_steps: int) -> jax.Array:
    """Simulate paths with the Pallas kernel: f32[B, n] -> f32[B, n+1]."""
    if dw.ndim != 2 or dw.shape[1] != n_steps:
        raise ValueError(f"dw must be [B, {n_steps}], got {dw.shape}")
    batch = dw.shape[0]
    padded = (batch + BATCH_TILE - 1) // BATCH_TILE * BATCH_TILE
    dw_p = jnp.pad(dw, ((0, padded - batch), (0, 0))) if padded != batch else dw
    n_tiles = padded // BATCH_TILE
    kernel = functools.partial(
        _milstein_kernel,
        mu=problem.mu,
        sigma=problem.sigma,
        s0=problem.s0,
        dt=problem.maturity / n_steps,
        n_steps=n_steps,
        geometric=problem.drift == "geometric",
    )
    s = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((BATCH_TILE, n_steps), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BATCH_TILE, n_steps + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, n_steps + 1), dw.dtype),
        interpret=INTERPRET,
    )(dw_p)
    return s[:batch]


def coupled_milstein_paths(
    dw_fine: jax.Array, problem: HedgingProblem, level: int
) -> tuple[jax.Array, jax.Array | None]:
    """Fine and coarse paths from one Brownian path (the MLMC coupling).

    Returns ``(s_fine[B, n_f+1], s_coarse[B, n_f/2+1] | None)``; the coarse
    path is ``None`` at level 0 (``F_{-1} := 0`` in the paper).
    """
    n_fine = problem.n_steps(level)
    s_fine = milstein_paths(dw_fine, problem, n_fine)
    if level == 0:
        return s_fine, None
    b, n = dw_fine.shape
    dw_coarse = dw_fine.reshape(b, n // 2, 2).sum(axis=-1)
    s_coarse = milstein_paths(dw_coarse, problem, n_fine // 2)
    return s_fine, s_coarse
