"""Pallas fused hedging-MLP kernel (forward + hand-written backward).

This is the FLOPs hot spot of the workload: the strategy network
``H_theta(t, S)`` is evaluated at every (path, time-step) pair, i.e. over
``batch * n_steps`` feature rows per gradient sample. The kernel fuses the
whole 2 -> H -> H -> 1 chain (dense + SiLU, dense + SiLU, dense + sigmoid)
per row tile, so activations never round-trip to HBM between layers.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is over row tiles of
``ROW_TILE`` rows; per tile the working set is

    x tile        ROW_TILE x 2
    w2            H x H          (the only MXU-shaped matmul, 32x32)
    activations   2 x ROW_TILE x H

which for ROW_TILE=128, H=32 is ~50 KiB of VMEM — comfortably double-
bufferable. The backward kernel recomputes nothing: it receives the saved
pre-activations and accumulates the weight gradients across the grid
(sequential-grid revisiting semantics).

Pallas primitives are not auto-differentiable, so ``hedge_mlp`` is wrapped
in ``jax.custom_vjp`` whose backward is itself a Pallas kernel. Kernels run
with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); the
interpret lowering inlines the kernel body into the HLO the Rust runtime
compiles, so there is no Python on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128

INTERPRET = True  # CPU PJRT target; see module docstring.


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _silu(x):
    return x * _sigmoid(x)


def _dsilu(x):
    """d/dx silu(x) = sig(x) * (1 + x * (1 - sig(x)))."""
    s = _sigmoid(x)
    return s * (1.0 + x * (1.0 - s))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                out_ref, z1_ref, z2_ref):
    """One row tile: fused dense+SiLU -> dense+SiLU -> dense+sigmoid.

    Saves the hidden pre-activations z1, z2 for the backward kernel.
    """
    x = x_ref[...]
    z1 = x @ w1_ref[...] + b1_ref[...][None, :]
    h1 = _silu(z1)
    z2 = h1 @ w2_ref[...] + b2_ref[...][None, :]
    h2 = _silu(z2)
    z3 = h2 @ w3_ref[...] + b3_ref[...][None, :]
    out_ref[...] = _sigmoid(z3)
    z1_ref[...] = z1
    z2_ref[...] = z2


def _pad_rows(x: jax.Array, tile: int) -> tuple[jax.Array, int]:
    rows = x.shape[0]
    padded = (rows + tile - 1) // tile * tile
    if padded != rows:
        x = jnp.pad(x, ((0, padded - rows), (0, 0)))
    return x, rows


def _mlp_forward_raw(x, w1, b1, w2, b2, w3, b3):
    """Runs the forward kernel; returns (out[rows], z1, z2, x_padded)."""
    n_in, hidden = w1.shape
    x_p, rows = _pad_rows(x, ROW_TILE)
    n_tiles = x_p.shape[0] // ROW_TILE
    row_spec = lambda width: pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    out, z1, z2 = pl.pallas_call(
        _fwd_kernel,
        grid=(n_tiles,),
        in_specs=[
            row_spec(n_in),
            full(w1), full(b1), full(w2), full(b2), full(w3), full(b3),
        ],
        out_specs=[row_spec(1), row_spec(hidden), row_spec(hidden)],
        out_shape=[
            jax.ShapeDtypeStruct((x_p.shape[0], 1), x.dtype),
            jax.ShapeDtypeStruct((x_p.shape[0], hidden), x.dtype),
            jax.ShapeDtypeStruct((x_p.shape[0], hidden), x.dtype),
        ],
        interpret=INTERPRET,
    )(x_p, w1, b1, w2, b2, w3, b3)
    return out[:rows, 0], z1, z2, x_p


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _bwd_kernel(g_ref, x_ref, z1_ref, z2_ref, w1_ref, w2_ref, w3_ref,
                     b3_ref,
                     dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref, dw3_ref,
                     db3_ref):
    """One row tile of the hand-written backward pass (see module docs)."""
    g = g_ref[...]
    x = x_ref[...]
    z1 = z1_ref[...]
    z2 = z2_ref[...]
    h1 = _silu(z1)
    h2 = _silu(z2)
    z3 = h2 @ w3_ref[...] + b3_ref[...][None, :]
    y = _sigmoid(z3)

    dz3 = g * y * (1.0 - y)
    dh2 = dz3 @ w3_ref[...].T
    dz2 = dh2 * _dsilu(z2)
    dh1 = dz2 @ w2_ref[...].T
    dz1 = dh1 * _dsilu(z1)
    dx_ref[...] = dz1 @ w1_ref[...].T

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref)
        db1_ref[...] = jnp.zeros_like(db1_ref)
        dw2_ref[...] = jnp.zeros_like(dw2_ref)
        db2_ref[...] = jnp.zeros_like(db2_ref)
        dw3_ref[...] = jnp.zeros_like(dw3_ref)
        db3_ref[...] = jnp.zeros_like(db3_ref)

    dw1_ref[...] += x.T @ dz1
    db1_ref[...] += jnp.sum(dz1, axis=0)
    dw2_ref[...] += h1.T @ dz2
    db2_ref[...] += jnp.sum(dz2, axis=0)
    dw3_ref[...] += h2.T @ dz3
    db3_ref[...] += jnp.sum(dz3, axis=0)


def _mlp_backward_raw(g, x_p, z1, z2, w1, w2, w3, b3, rows):
    n_in, hidden = w1.shape
    n_tiles = x_p.shape[0] // ROW_TILE
    g_p = jnp.zeros((x_p.shape[0], 1), x_p.dtype).at[:rows, 0].set(g)
    row_spec = lambda width: pl.BlockSpec((ROW_TILE, width), lambda i: (i, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    dx, dw1, db1, dw2, db2, dw3, db3 = pl.pallas_call(
        _bwd_kernel,
        grid=(n_tiles,),
        in_specs=[
            row_spec(1), row_spec(n_in), row_spec(hidden), row_spec(hidden),
            full(w1), full(w2), full(w3), full(b3),
        ],
        out_specs=[
            row_spec(n_in),
            full(w1), full(jnp.zeros(hidden)), full(w2),
            full(jnp.zeros(hidden)), full(w3), full(jnp.zeros(1)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x_p.shape, x_p.dtype),
            jax.ShapeDtypeStruct(w1.shape, w1.dtype),
            jax.ShapeDtypeStruct((hidden,), w1.dtype),
            jax.ShapeDtypeStruct(w2.shape, w1.dtype),
            jax.ShapeDtypeStruct((hidden,), w1.dtype),
            jax.ShapeDtypeStruct(w3.shape, w1.dtype),
            jax.ShapeDtypeStruct((1,), w1.dtype),
        ],
        interpret=INTERPRET,
    )(g_p, x_p, z1, z2, w1, w2, w3, b3)
    return dx[:rows], dw1, db1, dw2, db2, dw3, db3


# ---------------------------------------------------------------------------
# public entry point with custom VJP
# ---------------------------------------------------------------------------


@jax.custom_vjp
def hedge_mlp(x, w1, b1, w2, b2, w3, b3):
    """Fused hedging MLP: f32[rows, 2] feature rows -> f32[rows] holdings."""
    out, _, _, _ = _mlp_forward_raw(x, w1, b1, w2, b2, w3, b3)
    return out


def _hedge_mlp_fwd(x, w1, b1, w2, b2, w3, b3):
    out, z1, z2, x_p = _mlp_forward_raw(x, w1, b1, w2, b2, w3, b3)
    return out, (x_p, z1, z2, w1, w2, w3, b3, x.shape[0])


def _hedge_mlp_bwd(res, g):
    x_p, z1, z2, w1, w2, w3, b3, rows = res
    dx, dw1, db1, dw2, db2, dw3, db3 = _mlp_backward_raw(
        g, x_p, z1, z2, w1, w2, w3, b3, rows
    )
    return dx, dw1, db1, dw2, db2, dw3, db3


hedge_mlp.defvjp(_hedge_mlp_fwd, _hedge_mlp_bwd)
