"""Shared problem definition for the deep-hedging reproduction.

Single source of truth for the hyperparameters of the paper's Appendix-C
experiment (Ishikawa 2023). Both the JAX model (L2), the Pallas kernels
(L1) and the AOT manifest consume this; the Rust side reads the same
values back from ``artifacts/manifest.json``.

Paper values: c = 1, d = 1, b = 1.8, lmax = 6, mu = 1, sigma = 1, K = 3.
``s0`` is not given in the paper; we use the at-the-money convention
``s0 = K`` (documented in DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

DriftKind = Literal["additive", "geometric"]


@dataclasses.dataclass(frozen=True)
class HedgingProblem:
    """Deep-hedging problem instance (paper Appendix C)."""

    mu: float = 1.0
    sigma: float = 1.0
    strike: float = 3.0
    s0: float = 3.0
    maturity: float = 1.0
    #: number of time steps at level 0; level ``l`` uses ``n0 * 2**l``.
    n0: int = 4
    lmax: int = 6
    #: ``additive`` is the paper's literal SDE  dS = mu dt + sigma S dB;
    #: ``geometric`` is dS = mu S dt + sigma S dB (Black-Scholes validatable).
    drift: DriftKind = "additive"

    def n_steps(self, level: int) -> int:
        """Number of Milstein steps on the level-``level`` grid."""
        if level < 0:
            raise ValueError(f"level must be >= 0, got {level}")
        return self.n0 * (2**level)

    def dt(self, level: int) -> float:
        return self.maturity / self.n_steps(level)


@dataclasses.dataclass(frozen=True)
class MlpArch:
    """Hedging-strategy network H_theta(t, s): 2 -> hidden -> hidden -> 1.

    SiLU activations on hidden layers, sigmoid on the output so the holding
    is in [0, 1] (paper Appendix C).
    """

    n_in: int = 2
    hidden: int = 32

    @property
    def sizes(self) -> list[tuple[str, tuple[int, ...]]]:
        h = self.hidden
        return [
            ("w1", (self.n_in, h)),
            ("b1", (h,)),
            ("w2", (h, h)),
            ("b2", (h,)),
            ("w3", (h, 1)),
            ("b3", (1,)),
            ("p0", (1,)),
        ]

    @property
    def n_params(self) -> int:
        total = 0
        for _, shape in self.sizes:
            n = 1
            for s in shape:
                n *= s
            total += n
        return total


DEFAULT_PROBLEM = HedgingProblem()
DEFAULT_ARCH = MlpArch()

#: Per-level gradient-chunk batch sizes baked into the AOT artifacts.
#: The Rust runtime accumulates as many chunks as the N_l allocation needs,
#: so these only fix the granularity (and keep B*n a multiple of the MLP
#: row tile so the Pallas grid needs no padding on the hot path).
#: Sized so each execution is compute- rather than dispatch-bound: PJRT
#: CPU dispatch costs ~270us/execution (EXPERIMENTS.md §Perf), so low
#: levels use larger batches (B*n = 512 rows uniformly for l <= 4).
GRAD_CHUNK = {0: 128, 1: 64, 2: 32, 3: 16, 4: 8, 5: 8, 6: 8}
#: Batch for the held-out loss evaluation at the finest level.
EVAL_CHUNK = 256
#: Batch for per-sample diagnostics (Figure 1 artifacts).
DIAG_CHUNK = 32
