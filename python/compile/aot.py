"""AOT lowering: JAX model -> HLO *text* artifacts + manifest.json.

Runs exactly once (``make artifacts``); the Rust runtime is self-contained
afterwards. Python never executes on the training hot path.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (for problem defaults: lmax = 6, n_l = 4 * 2^l):

    grad_l{0..6}.hlo.txt      (params, dw[B_l, n_l]) -> (dloss, grad)
    grad_naive.hlo.txt        (params, dw[B, n_6])   -> (loss, grad)
    loss_eval.hlo.txt         (params, dw[B_e, n_6]) -> (loss,)
    grad_norms_l{0..6}.hlo.txt  per-sample ||grad||^2   (Figure 1 left)
    smoothness_l{0..6}.hlo.txt  pathwise smoothness     (Figure 1 right)
    path_eval_l{0..6}.hlo.txt   fine/coarse terminal S  (engine cross-check)
    init_params.bin           raw little-endian f32 He init (seed 0)
    manifest.json             shapes/dtypes/levels for every entry point —
                              the single source of truth the Rust loader
                              validates against.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .problem import (
    DEFAULT_ARCH,
    DEFAULT_PROBLEM,
    DIAG_CHUNK,
    EVAL_CHUNK,
    GRAD_CHUNK,
    HedgingProblem,
    MlpArch,
)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, F32)


def _io_meta(specs) -> list[dict]:
    return [{"shape": list(s.shape), "dtype": "f32"} for s in specs]


@dataclasses.dataclass
class Entry:
    name: str
    kind: str
    fn: object
    in_specs: list
    out_meta: list[dict]
    level: int | None = None
    batch: int | None = None
    n_steps: int | None = None


def build_entries(problem: HedgingProblem, arch: MlpArch) -> list[Entry]:
    p = arch.n_params
    n_max = problem.n_steps(problem.lmax)
    entries: list[Entry] = []

    for lvl in range(problem.lmax + 1):
        n = problem.n_steps(lvl)
        b = GRAD_CHUNK[min(lvl, max(GRAD_CHUNK))]
        entries.append(
            Entry(
                name=f"grad_l{lvl}",
                kind="grad_coupled",
                fn=model.make_grad_coupled(problem, arch, lvl),
                in_specs=[_spec(p), _spec(b, n)],
                out_meta=[
                    {"shape": [], "dtype": "f32"},
                    {"shape": [p], "dtype": "f32"},
                ],
                level=lvl,
                batch=b,
                n_steps=n,
            )
        )

    b_naive = GRAD_CHUNK[max(GRAD_CHUNK)]
    entries.append(
        Entry(
            name="grad_naive",
            kind="grad_naive",
            fn=model.make_grad_naive(problem, arch),
            in_specs=[_spec(p), _spec(b_naive, n_max)],
            out_meta=[
                {"shape": [], "dtype": "f32"},
                {"shape": [p], "dtype": "f32"},
            ],
            level=problem.lmax,
            batch=b_naive,
            n_steps=n_max,
        )
    )
    entries.append(
        Entry(
            name="loss_eval",
            kind="loss_eval",
            fn=model.make_loss_eval(problem, arch),
            in_specs=[_spec(p), _spec(EVAL_CHUNK, n_max)],
            out_meta=[{"shape": [], "dtype": "f32"}],
            level=problem.lmax,
            batch=EVAL_CHUNK,
            n_steps=n_max,
        )
    )

    for lvl in range(problem.lmax + 1):
        n = problem.n_steps(lvl)
        entries.append(
            Entry(
                name=f"grad_norms_l{lvl}",
                kind="grad_norms",
                fn=model.make_grad_norms(problem, arch, lvl),
                in_specs=[_spec(p), _spec(DIAG_CHUNK, n)],
                out_meta=[{"shape": [DIAG_CHUNK], "dtype": "f32"}],
                level=lvl,
                batch=DIAG_CHUNK,
                n_steps=n,
            )
        )
        entries.append(
            Entry(
                name=f"smoothness_l{lvl}",
                kind="smoothness",
                fn=model.make_smoothness(problem, arch, lvl),
                in_specs=[_spec(p), _spec(p), _spec(DIAG_CHUNK, n)],
                out_meta=[{"shape": [DIAG_CHUNK], "dtype": "f32"}],
                level=lvl,
                batch=DIAG_CHUNK,
                n_steps=n,
            )
        )
        entries.append(
            Entry(
                name=f"path_eval_l{lvl}",
                kind="path_eval",
                fn=model.make_path_eval(problem, lvl),
                in_specs=[_spec(DIAG_CHUNK, n)],
                out_meta=[
                    {"shape": [DIAG_CHUNK], "dtype": "f32"},
                    {"shape": [DIAG_CHUNK], "dtype": "f32"},
                ],
                level=lvl,
                batch=DIAG_CHUNK,
                n_steps=n,
            )
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact dir")
    ap.add_argument("--drift", default=None, choices=["additive", "geometric"])
    ap.add_argument("--lmax", type=int, default=None)
    args = ap.parse_args()

    problem = DEFAULT_PROBLEM
    if args.drift is not None:
        problem = dataclasses.replace(problem, drift=args.drift)
    if args.lmax is not None:
        problem = dataclasses.replace(problem, lmax=args.lmax)
    arch = DEFAULT_ARCH

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    entries = build_entries(problem, arch)
    manifest_entries = []
    for e in entries:
        lowered = jax.jit(e.fn).lower(*e.in_specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{e.name}.hlo.txt"
        path.write_text(text)
        manifest_entries.append(
            {
                "name": e.name,
                "kind": e.kind,
                "path": path.name,
                "level": e.level,
                "batch": e.batch,
                "n_steps": e.n_steps,
                "inputs": _io_meta(e.in_specs),
                "outputs": e.out_meta,
            }
        )
        print(f"  lowered {e.name:>18s}  ({len(text)} chars)")

    init = np.asarray(model.init_params(0, arch), dtype=np.float32)
    (out_dir / "init_params.bin").write_bytes(init.tobytes())

    manifest = {
        "format_version": 1,
        "problem": dataclasses.asdict(problem),
        "arch": {"n_in": arch.n_in, "hidden": arch.hidden},
        "n_params": arch.n_params,
        "param_layout": [
            {"name": n, "shape": list(s)} for n, s in arch.sizes
        ],
        "init_params": "init_params.bin",
        "entries": manifest_entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(entries)} HLO artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
