"""L2 model correctness: objective, gradients, coupling decay, init."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.problem import DEFAULT_ARCH, DEFAULT_PROBLEM

ARCH = DEFAULT_ARCH
PROB = DEFAULT_PROBLEM


def _dw(seed, batch, level):
    n = PROB.n_steps(level)
    return jax.random.normal(jax.random.PRNGKey(seed), (batch, n)) * np.sqrt(
        PROB.dt(level)
    )


@pytest.fixture(scope="module")
def params():
    return model.init_params(0, ARCH)


class TestObjective:
    def test_pallas_loss_matches_ref(self, params):
        for level in [0, 1, 3]:
            dw = _dw(level, 16, level)
            got = model.coupled_loss(params, dw, PROB, ARCH, level)
            want = ref.coupled_loss_ref(params, dw, PROB, ARCH, level)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_naive_loss_matches_ref_finest(self, params):
        dw = _dw(9, 8, PROB.lmax)
        got = model.naive_loss(params, dw, PROB, ARCH)
        want = ref.hedging_loss_ref(
            params, dw, PROB, ARCH, PROB.n_steps(PROB.lmax)
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_telescoping_identity(self, params):
        """F_hat_lmax(x, xi) == sum_l Delta_l F_hat(x, xi) on the same path.

        The MLMC decomposition must telescope exactly when every level sees
        the same Brownian path (coarsened consistently).
        """
        lmax = 3
        prob = dataclasses.replace(PROB, lmax=lmax)
        dw_fine = _dw(11, 32, lmax)
        total = ref.hedging_loss_ref(
            params, dw_fine, prob, ARCH, prob.n_steps(lmax)
        )
        acc = 0.0
        dw = dw_fine
        for level in range(lmax, -1, -1):
            acc += ref.coupled_loss_ref(params, dw, prob, ARCH, level)
            if level > 0:
                dw = ref.coarsen_increments(dw)
        np.testing.assert_allclose(acc, total, rtol=1e-4, atol=1e-6)

    def test_loss_nonnegative_at_level0(self, params):
        dw = _dw(1, 64, 0)
        loss = ref.coupled_loss_ref(params, dw, PROB, ARCH, 0)
        assert float(loss) >= 0.0


class TestGradients:
    def test_grad_matches_finite_differences(self, params):
        level = 1
        dw = _dw(2, 8, level)
        fn = model.make_grad_coupled(PROB, ARCH, level)
        loss, grad = fn(params, dw)
        rng = np.random.default_rng(0)
        idx = rng.choice(ARCH.n_params, size=12, replace=False)
        eps = 1e-3
        for i in idx:
            e = jnp.zeros_like(params).at[i].set(eps)
            lp = ref.coupled_loss_ref(params + e, dw, PROB, ARCH, level)
            lm = ref.coupled_loss_ref(params - e, dw, PROB, ARCH, level)
            fd = (lp - lm) / (2 * eps)
            assert abs(float(grad[i]) - float(fd)) < 5e-3 * max(
                1.0, abs(float(fd))
            ), f"param {i}: grad {grad[i]} vs fd {fd}"

    def test_grad_pallas_matches_grad_ref(self, params):
        for level in [0, 2]:
            dw = _dw(level + 5, 8, level)
            g_pallas = jax.grad(model.coupled_loss)(params, dw, PROB, ARCH, level)
            g_ref = jax.grad(ref.coupled_loss_ref)(params, dw, PROB, ARCH, level)
            np.testing.assert_allclose(g_pallas, g_ref, rtol=1e-3, atol=1e-6)

    def test_p0_gradient_is_mean_residual(self, params):
        """dL/dp0 = -2 E[residual] in closed form — sanity anchor."""
        dw = _dw(3, 32, 0)
        g = jax.grad(ref.coupled_loss_ref)(params, dw, PROB, ARCH, 0)
        r = ref.hedging_residual_ref(params, dw, PROB, ARCH, PROB.n_steps(0))
        np.testing.assert_allclose(
            g[-1], -2.0 * jnp.mean(r), rtol=1e-4, atol=1e-6
        )


class TestAssumptionDecay:
    """Empirical sanity that Assumptions 1-3 hold on this problem —
    the premise of the whole paper (checked at full scale in Figure 1)."""

    def test_variance_decays_with_level(self, params):
        fn = lambda lvl: model.make_grad_norms(PROB, ARCH, lvl)
        norms = []
        for level in [0, 2, 4]:
            dw = _dw(21, 32, level)
            (vals,) = fn(level)(params, dw)
            norms.append(float(jnp.mean(vals)))
        assert norms[2] < norms[1] < norms[0], norms

    def test_smoothness_decays_with_level(self, params):
        p2 = params + 0.01 * jax.random.normal(
            jax.random.PRNGKey(5), params.shape
        )
        vals = []
        for level in [0, 2, 4]:
            dw = _dw(22, 32, level)
            (v,) = model.make_smoothness(PROB, ARCH, level)(params, p2, dw)
            vals.append(float(jnp.mean(v)))
        assert vals[2] < vals[0], vals


class TestInit:
    def test_deterministic(self):
        a = model.init_params(7, ARCH)
        b = model.init_params(7, ARCH)
        np.testing.assert_array_equal(a, b)

    def test_shape_and_zero_biases(self):
        p = model.init_params(0, ARCH)
        assert p.shape == (ARCH.n_params,)
        d = ref.unflatten_params(p, ARCH)
        np.testing.assert_array_equal(d["b1"], 0.0)
        np.testing.assert_array_equal(d["p0"], 0.0)

    def test_flatten_roundtrip(self):
        p = model.init_params(1, ARCH)
        d = ref.unflatten_params(p, ARCH)
        np.testing.assert_array_equal(ref.flatten_params(d, ARCH), p)


class TestSmoothnessFunction:
    def test_identical_params_give_zero(self, params):
        dw = _dw(4, 32, 1)
        (v,) = model.make_smoothness(PROB, ARCH, 1)(params, params, dw)
        # num = 0, den clamped at 1e-12 -> exactly 0
        np.testing.assert_allclose(v, 0.0, atol=1e-6)


class TestPathEval:
    def test_level0_coarse_equals_fine(self, params):
        dw = _dw(6, 32, 0)
        f, c = model.make_path_eval(PROB, 0)(dw)
        np.testing.assert_array_equal(f, c)

    def test_matches_ref_terminal(self, params):
        dw = _dw(8, 32, 2)
        f, c = model.make_path_eval(PROB, 2)(dw)
        sf = ref.milstein_path_ref(dw, PROB, PROB.n_steps(2))
        sc = ref.milstein_path_ref(
            ref.coarsen_increments(dw), PROB, PROB.n_steps(1)
        )
        np.testing.assert_allclose(f, sf[:, -1], rtol=1e-5)
        np.testing.assert_allclose(c, sc[:, -1], rtol=1e-5)
