"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept by hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.milstein import coupled_milstein_paths, milstein_paths
from compile.kernels.mlp import ROW_TILE, hedge_mlp
from compile.problem import DEFAULT_ARCH, DEFAULT_PROBLEM, HedgingProblem

ARCH = DEFAULT_ARCH
PROB = DEFAULT_PROBLEM

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _params(seed: int) -> dict:
    flat = jax.random.normal(
        jax.random.PRNGKey(seed), (ARCH.n_params,), jnp.float32
    ) * 0.3
    return ref.unflatten_params(flat, ARCH), flat


# ---------------------------------------------------------------------------
# hedge_mlp forward
# ---------------------------------------------------------------------------


class TestMlpForward:
    @hypothesis.given(
        rows=st.integers(1, 3 * ROW_TILE + 7), seed=st.integers(0, 10)
    )
    def test_matches_ref_any_row_count(self, rows, seed):
        p, _ = _params(seed)
        x = jax.random.normal(jax.random.PRNGKey(seed + 99), (rows, 2))
        got = hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
        want = ref.mlp_ref(p, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_output_in_unit_interval(self):
        p, _ = _params(3)
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 2)) * 10.0
        h = hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
        assert jnp.all(h >= 0.0) and jnp.all(h <= 1.0)

    def test_exact_tile_multiple(self):
        p, _ = _params(1)
        x = jax.random.normal(jax.random.PRNGKey(5), (2 * ROW_TILE, 2))
        got = hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
        np.testing.assert_allclose(got, ref.mlp_ref(p, x), rtol=1e-5, atol=1e-6)

    def test_single_row(self):
        p, _ = _params(2)
        x = jnp.array([[0.5, 3.0]], jnp.float32)
        got = hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
        np.testing.assert_allclose(got, ref.mlp_ref(p, x), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# hedge_mlp backward (custom VJP kernel)
# ---------------------------------------------------------------------------


class TestMlpBackward:
    def _grads(self, fn, flat, x):
        return jax.grad(fn)(flat, x)

    @hypothesis.given(rows=st.sampled_from([1, 7, 128, 200, 300]), seed=st.integers(0, 5))
    def test_param_grads_match_autodiff_of_ref(self, rows, seed):
        _, flat = _params(seed)
        x = jax.random.normal(jax.random.PRNGKey(seed + 7), (rows, 2))

        def loss_k(fl, x):
            p = ref.unflatten_params(fl, ARCH)
            h = hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
            return jnp.sum(jnp.sin(h) * jnp.cos(jnp.arange(rows) * 0.1))

        def loss_r(fl, x):
            p = ref.unflatten_params(fl, ARCH)
            return jnp.sum(jnp.sin(ref.mlp_ref(p, x)) * jnp.cos(jnp.arange(rows) * 0.1))

        gk = self._grads(loss_k, flat, x)
        gr = self._grads(loss_r, flat, x)
        np.testing.assert_allclose(gk, gr, rtol=5e-4, atol=1e-5)

    def test_input_grads_match(self):
        p, flat = _params(0)
        x = jax.random.normal(jax.random.PRNGKey(11), (150, 2))

        gk = jax.grad(
            lambda x: jnp.sum(
                hedge_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]) ** 2
            )
        )(x)
        gr = jax.grad(lambda x: jnp.sum(ref.mlp_ref(p, x) ** 2))(x)
        np.testing.assert_allclose(gk, gr, rtol=5e-4, atol=1e-6)

    def test_grad_accumulation_across_tiles(self):
        """Weight grads must sum over *all* grid tiles, not just the last."""
        p, flat = _params(4)
        x = jax.random.normal(jax.random.PRNGKey(3), (4 * ROW_TILE, 2))

        def loss(fl):
            pp = ref.unflatten_params(fl, ARCH)
            return jnp.sum(
                hedge_mlp(x, pp["w1"], pp["b1"], pp["w2"], pp["b2"], pp["w3"], pp["b3"])
            )

        def loss_half(fl):
            pp = ref.unflatten_params(fl, ARCH)
            return jnp.sum(
                hedge_mlp(
                    x[: 2 * ROW_TILE],
                    pp["w1"], pp["b1"], pp["w2"], pp["b2"], pp["w3"], pp["b3"],
                )
            )

        g_full = jax.grad(loss)(flat)
        g_half = jax.grad(loss_half)(flat)
        # The full gradient must differ from any single-slice gradient.
        assert float(jnp.linalg.norm(g_full - g_half)) > 1e-4


# ---------------------------------------------------------------------------
# milstein kernel
# ---------------------------------------------------------------------------


def _dw(seed: int, batch: int, n: int, dt: float) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (batch, n)) * np.sqrt(dt)


class TestMilstein:
    @hypothesis.given(
        batch=st.sampled_from([1, 5, 64, 128, 130]),
        level=st.integers(0, 4),
        seed=st.integers(0, 5),
    )
    def test_matches_ref(self, batch, level, seed):
        n = PROB.n_steps(level)
        dw = _dw(seed, batch, n, PROB.dt(level))
        got = milstein_paths(dw, PROB, n)
        want = ref.milstein_path_ref(dw, PROB, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_geometric_drift_matches_ref(self):
        import dataclasses

        prob = dataclasses.replace(PROB, drift="geometric")
        dw = _dw(0, 32, 16, prob.maturity / 16)
        got = milstein_paths(dw, prob, 16)
        want = ref.milstein_path_ref(dw, prob, 16)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_initial_value(self):
        dw = _dw(1, 8, 4, PROB.dt(0))
        s = milstein_paths(dw, PROB, 4)
        np.testing.assert_allclose(s[:, 0], PROB.s0)

    def test_zero_noise_matches_deterministic_recurrence(self):
        """With dw = 0 Milstein reduces to
        S+ = S + mu dt - 1/2 sigma^2 S dt  (the dW^2 - dt correction keeps
        its -dt part at zero noise) — check against the scalar recurrence."""
        n = 32
        dw = jnp.zeros((4, n), jnp.float32)
        s = milstein_paths(dw, PROB, n)
        dt = PROB.maturity / n
        want = [PROB.s0]
        for _ in range(n):
            prev = want[-1]
            want.append(prev + PROB.mu * dt - 0.5 * PROB.sigma**2 * prev * dt)
        np.testing.assert_allclose(s[0], np.array(want), rtol=1e-5)

    def test_coupling_strong_convergence(self):
        """|S_fine(T) - S_coarse(T)| must shrink as the level increases —
        the foundation of Assumption 2 (variance decay)."""
        errs = []
        for level in range(1, 6):
            n = PROB.n_steps(level)
            dw = _dw(42, 512, n, PROB.dt(level))
            s_f, s_c = coupled_milstein_paths(dw, PROB, level)
            errs.append(float(jnp.mean((s_f[:, -1] - s_c[:, -1]) ** 2)))
        for a, b in zip(errs, errs[1:]):
            assert b < a, f"coupling error not decreasing: {errs}"
        # Milstein is strong order 1 => MSE decay ~ 2^{-2l}; allow slack.
        assert errs[-1] < errs[0] / 16

    def test_coarsen_preserves_total_increment(self):
        dw = _dw(7, 16, 32, 0.01)
        dc = ref.coarsen_increments(dw)
        np.testing.assert_allclose(
            dc.sum(axis=1), dw.sum(axis=1), rtol=1e-5, atol=1e-6
        )

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            milstein_paths(jnp.zeros((4, 8)), PROB, 16)
        with pytest.raises(ValueError):
            ref.coarsen_increments(jnp.zeros((4, 7)))
