"""AOT pipeline: manifest integrity and HLO-text artifact properties."""

import json
import pathlib

import numpy as np
import pytest

from compile import aot, model
from compile.problem import DEFAULT_ARCH, DEFAULT_PROBLEM

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


class TestManifest:
    def test_problem_matches_defaults(self, manifest):
        assert manifest["problem"]["lmax"] == DEFAULT_PROBLEM.lmax
        assert manifest["problem"]["strike"] == DEFAULT_PROBLEM.strike
        assert manifest["n_params"] == DEFAULT_ARCH.n_params

    def test_every_entry_file_exists(self, manifest):
        for e in manifest["entries"]:
            assert (ART / e["path"]).exists(), e["name"]

    def test_grad_entries_cover_all_levels(self, manifest):
        grads = [e for e in manifest["entries"] if e["kind"] == "grad_coupled"]
        assert sorted(e["level"] for e in grads) == list(
            range(DEFAULT_PROBLEM.lmax + 1)
        )

    def test_entry_shapes_consistent(self, manifest):
        p = manifest["n_params"]
        for e in manifest["entries"]:
            if e["kind"] in ("grad_coupled", "grad_naive"):
                assert e["inputs"][0]["shape"] == [p]
                assert e["inputs"][1]["shape"] == [e["batch"], e["n_steps"]]
                assert e["outputs"][1]["shape"] == [p]
            if e["kind"] == "grad_coupled":
                assert e["n_steps"] == DEFAULT_PROBLEM.n_steps(e["level"])

    def test_param_layout_totals_n_params(self, manifest):
        total = sum(int(np.prod(x["shape"])) for x in manifest["param_layout"])
        assert total == manifest["n_params"]

    def test_unique_names(self, manifest):
        names = [e["name"] for e in manifest["entries"]]
        assert len(names) == len(set(names))


class TestArtifacts:
    def test_hlo_text_has_entry_computation(self, manifest):
        for e in manifest["entries"][:4]:
            text = (ART / e["path"]).read_text()
            assert "ENTRY" in text, e["name"]
            assert "HloModule" in text

    def test_init_params_binary(self, manifest):
        raw = (ART / manifest["init_params"]).read_bytes()
        got = np.frombuffer(raw, dtype=np.float32)
        want = np.asarray(model.init_params(0, DEFAULT_ARCH))
        np.testing.assert_array_equal(got, want)

    def test_no_custom_calls_in_hot_path(self, manifest):
        """interpret=True must have inlined the Pallas kernels: a Mosaic
        custom-call in the HLO would be unloadable by the CPU PJRT client."""
        for e in manifest["entries"]:
            if e["kind"] in ("grad_coupled", "grad_naive", "loss_eval"):
                text = (ART / e["path"]).read_text()
                assert "custom-call" not in text.lower(), e["name"]


class TestEntryBuilder:
    def test_build_entries_counts(self):
        entries = aot.build_entries(DEFAULT_PROBLEM, DEFAULT_ARCH)
        lmax = DEFAULT_PROBLEM.lmax
        # grads per level + naive + loss_eval + 3 diagnostics per level
        assert len(entries) == (lmax + 1) + 2 + 3 * (lmax + 1)

    def test_names_match_levels(self):
        entries = aot.build_entries(DEFAULT_PROBLEM, DEFAULT_ARCH)
        byname = {e.name: e for e in entries}
        assert byname["grad_l3"].level == 3
        assert byname["grad_l3"].n_steps == DEFAULT_PROBLEM.n_steps(3)
