//! Figure-1 reproduction: empirical verification of the paper's
//! Assumptions 2 and 3 on the deep-hedging problem.
//!
//! Tracks, along an optimization trajectory,
//! * `E||∇Δ_l F̂(x, ξ)||²` per level (variance proxy, Assumption 2), and
//! * the pathwise smoothness `||∇Δ_lF̂(x_{t+1},ξ) − ∇Δ_lF̂(x_t,ξ)|| / ||x_{t+1} − x_t||`
//!   (Assumption 3),
//! then fits the decay exponents `b̂` and `d̂` by log-linear regression.
//! The paper reads b ≈ 2 and d ≈ 1 off these plots; those are exactly the
//! parameters that make delayed MLMC applicable (b > c, schedule ~ 2^{dl}).
//!
//! ```sh
//! cargo run --release --example assumption_check -- --steps 40
//! ```

use std::path::PathBuf;

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::experiments::ExperimentRunner;
use dmlmc::util::cli::{Command, Opt};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("assumption_check", "Figure-1 decay diagnostics")
        .opt(Opt::with_default("steps", "trajectory length", "40"))
        .opt(Opt::with_default("snapshots", "measurement points", "8"))
        .opt(Opt::with_default("out-dir", "output dir", "out/assumptions"))
        .opt(Opt::value("backend", "xla|native (default: auto)"));
    let (_, args) = match cmd.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default_paper();
    cfg.train.steps = args.parse_usize("steps")?.unwrap();
    cfg.mlmc.n_effective = 128;
    cfg.runtime.backend = match args.get("backend") {
        Some(b) => Backend::parse(b).expect("backend must be xla|native"),
        None if cfg.runtime.artifacts_dir.join("manifest.json").exists() => Backend::Xla,
        None => Backend::Native,
    };
    let out_dir = PathBuf::from(args.get_or("out-dir", "out/assumptions"));
    let snapshots = args.parse_usize("snapshots")?.unwrap();

    eprintln!(
        "assumption_check: {} steps, {} snapshots, backend = {}",
        cfg.train.steps,
        snapshots,
        cfg.runtime.backend.name()
    );
    let fig = ExperimentRunner::new(&cfg).figure1(snapshots)?;

    println!("\n=== Figure 1 (left): variance proxy E||grad Delta_l||^2 ===");
    println!("{:<6} {:>14} {:>12} {:>16}", "level", "mean", "std", "mean/2^(-b l)");
    for (l, (m, s)) in fig.grad_norms.per_level.iter().enumerate() {
        let fit = fig.grad_norms.per_level[1].0 * 2f64.powf(-fig.b_hat * (l as f64 - 1.0));
        println!("{l:<6} {m:>14.6e} {s:>12.2e} {:>16.3}", m / fit.max(1e-300));
    }
    println!("\n=== Figure 1 (right): pathwise smoothness ===");
    println!("{:<6} {:>14} {:>12}", "level", "mean", "std");
    for (l, (m, s)) in fig.smoothness.per_level.iter().enumerate() {
        println!("{l:<6} {m:>14.6e} {s:>12.2e}");
    }
    println!("\nfitted decay exponents:");
    println!(
        "  b_hat = {:.3}   (paper reads ~1.8-2 from its Figure 1; Assumption 2 needs b > c = 1)",
        fig.b_hat
    );
    println!("  d_hat = {:.3}   (paper reads ~1; sets the delay schedule 2^(d l))", fig.d_hat);

    std::fs::create_dir_all(&out_dir)?;
    let mut csv = String::from("level,grad_norm_mean,grad_norm_std,smooth_mean,smooth_std\n");
    for l in 0..fig.grad_norms.per_level.len() {
        let (gm, gs) = fig.grad_norms.per_level[l];
        let (sm, ss) = fig.smoothness.per_level[l];
        csv.push_str(&format!("{l},{gm},{gs},{sm},{ss}\n"));
    }
    std::fs::write(out_dir.join("figure1.csv"), csv)?;
    eprintln!("wrote {}", out_dir.join("figure1.csv").display());
    Ok(())
}
