//! Scenario sweep: for every registered scenario (SDE dynamics x payoff),
//! fit the variance-decay exponent `b` of Assumption 2 and compare the
//! measured parallel cost of standard MLMC vs delayed MLMC — the paper's
//! parallel-complexity advantage, shown to be scenario-generic.
//!
//! Runs entirely on the native engine (no artifacts needed):
//!
//! ```sh
//! cargo run --release --example scenario_sweep
//! ```

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::experiments::ExperimentRunner;
use dmlmc::scenarios::all_scenario_names;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 30;
    cfg.train.eval_every = 30;
    cfg.mlmc.n_effective = 64;
    cfg.train.dmlmc_warmup = 0;

    let names = all_scenario_names();
    println!(
        "scenario sweep: {} scenarios, {} SGD steps each (MLMC + DMLMC), \
         b fitted over levels 1..={}\n",
        names.len(),
        cfg.train.steps,
        cfg.problem.lmax
    );

    let rows = ExperimentRunner::new(&cfg).scenario_sweep(&names)?;
    println!("\n{}", ExperimentRunner::render_scenario_table(&rows));

    println!(
        "reading the table: `b_hat` is the fitted decay exponent of \
         E||grad Delta_l F||^2 (Assumption 2 wants b > c = {}); `ratio` is\n\
         the measured MLMC/DMLMC total parallel cost — the paper's \
         advantage. Note the discontinuous payoffs (digital, and the\n\
         barrier uo-call/di-put whose knock events are grid-dependent): \
         their weaker decay is the classic hard case of the MLMC\n\
         literature. The heston-* rows run the 2-factor stochastic-vol \
         dynamics through the same estimator.",
        cfg.mlmc.c
    );
    Ok(())
}
