//! Domain example: what did the network actually learn?
//!
//! Trains under the martingale GBM (where the *exact* optimal strategy is
//! the Black–Scholes delta) and compares the learned holding H(t, s)
//! against N(d1) on a (t, s) grid, plus the learned price p0 against the
//! closed form. This is the "is the hedging model right" check a
//! practitioner would run before trusting the estimator comparison.
//!
//! ```sh
//! cargo run --release --example hedge_strategy -- --steps 400
//! ```

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::engine::mlp::{holding, MlpParams, OFF_P0};
use dmlmc::hedging::blackscholes::{bs_call_delta, bs_call_price};
use dmlmc::hedging::Drift;
use dmlmc::util::cli::{Command, Opt};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("hedge_strategy", "learned strategy vs BS delta")
        .opt(Opt::with_default("steps", "SGD steps", "400"))
        .opt(Opt::with_default("n-effective", "effective batch N", "256"))
        .opt(Opt::value("backend", "xla|native (default: native)"));
    let (_, args) = match cmd.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default_paper();
    cfg.problem.drift = Drift::Geometric;
    cfg.problem.mu = 0.0; // martingale measure: optimal H = BS delta
    cfg.train.steps = args.parse_usize("steps")?.unwrap();
    cfg.train.eval_every = cfg.train.steps;
    cfg.train.lr = 0.08;
    cfg.mlmc.n_effective = args.parse_usize("n-effective")?.unwrap();
    cfg.runtime.backend = match args.get("backend") {
        Some(b) => Backend::parse(b).expect("backend must be xla|native"),
        None => Backend::Native,
    };

    eprintln!(
        "hedge_strategy: training {} steps under martingale GBM (backend {})",
        cfg.train.steps,
        cfg.runtime.backend.name()
    );
    let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0)?;
    let curve = tr.run()?;
    eprintln!(
        "loss {:.4} -> {:.4}",
        curve.points.first().unwrap().loss,
        curve.final_loss().unwrap()
    );

    let params = tr.params.clone();
    let view = MlpParams::new(&params);
    let (k, sigma, t_mat) = (cfg.problem.strike, cfg.problem.sigma, cfg.problem.maturity);

    println!("\n=== learned H(t, s) vs Black–Scholes delta N(d1) ===");
    println!(
        "{:>6} {:>6} {:>12} {:>12} {:>10}",
        "t", "s", "learned H", "BS delta", "abs err"
    );
    let mut worst: f64 = 0.0;
    let mut mean_err = 0.0;
    let mut count = 0;
    for &t in &[0.1f32, 0.5, 0.9] {
        for &s in &[1.5f32, 2.5, 3.0, 3.5, 5.0] {
            let h = holding(&view, t, s) as f64;
            let delta = bs_call_delta(s as f64, k, sigma, t_mat - t as f64);
            let err = (h - delta).abs();
            worst = worst.max(err);
            mean_err += err;
            count += 1;
            println!("{t:>6.1} {s:>6.1} {h:>12.4} {delta:>12.4} {err:>10.4}");
        }
    }
    mean_err /= count as f64;

    let p0 = params[OFF_P0] as f64;
    let bs = bs_call_price(cfg.problem.s0, k, sigma, t_mat);
    println!("\nlearned price p0 = {p0:.4}  vs  Black–Scholes = {bs:.4}  ({:+.2}%)",
        100.0 * (p0 - bs) / bs);
    println!("strategy error: mean {mean_err:.4}, worst {worst:.4} (grid above)");
    println!(
        "\n(the MLP only sees ~{} SGD steps here; the paper's point is the\n\
         estimator comparison, not a fully converged hedge — push --steps\n\
         higher to watch both errors shrink)",
        cfg.train.steps
    );
    Ok(())
}
