//! Table-1 reproduction: theory formulas vs measured cost accounting for
//! the three methods, plus a finite-processor PRAM sweep showing *where*
//! the parallel-complexity advantage of delayed MLMC kicks in.
//!
//! ```sh
//! cargo run --release --example complexity_table -- --steps 64
//! ```

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::experiments::ExperimentRunner;
use dmlmc::mlmc::LevelAllocation;
use dmlmc::parallel::{pram::LevelJob, CostModel, PramMachine};
use dmlmc::util::cli::{Command, Opt};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("complexity_table", "Table-1 theory vs measured")
        .opt(Opt::with_default("steps", "steps per measured run", "64"))
        .opt(Opt::with_default("n-effective", "effective batch N", "128"))
        .opt(Opt::value("backend", "xla|native (default: native)"));
    let (_, args) = match cmd.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default_paper();
    cfg.train.steps = args.parse_usize("steps")?.unwrap();
    cfg.train.eval_every = cfg.train.steps;
    cfg.mlmc.n_effective = args.parse_usize("n-effective")?.unwrap();
    cfg.runtime.backend = match args.get("backend") {
        Some(b) => Backend::parse(b).expect("backend must be xla|native"),
        None => Backend::Native,
    };

    println!(
        "=== Table 1: theory vs measured over T = {} steps (N = {}, lmax = {}) ===\n",
        cfg.train.steps, cfg.mlmc.n_effective, cfg.problem.lmax
    );
    let runner = ExperimentRunner::new(&cfg);
    let (theory, measured) = runner.table1()?;
    println!("{}", ExperimentRunner::render_table1(&theory, &measured));

    println!(
        "average per-step parallel depth: naive/mlmc = {} (2^c·lmax), dmlmc measured = \
         {:.2}, schedule-predicted = {:.2}, theory Σ2^((c-d)l) = {:.2}",
        2f64.powi(cfg.problem.lmax as i32),
        measured[2].avg_depth,
        runner.predicted_avg_depth(1 << 14),
        dmlmc::mlmc::theory::geom_sum(cfg.mlmc.c - cfg.mlmc.d, cfg.problem.lmax),
    );

    // ----- finite-P PRAM sweep: step makespans -----------------------
    println!("\n=== PRAM makespan per SGD step (work-time scheduling, Brent bound) ===");
    let model = CostModel::new(cfg.mlmc.c);
    let alloc = LevelAllocation::paper(
        cfg.problem.lmax,
        cfg.mlmc.n_effective,
        cfg.mlmc.b,
        cfg.mlmc.c,
    );
    let mlmc_jobs: Vec<LevelJob> = (0..=cfg.problem.lmax)
        .map(|l| LevelJob { level: l, n_samples: alloc.n(l) })
        .collect();
    let naive_jobs = [LevelJob {
        level: cfg.problem.lmax,
        n_samples: cfg.mlmc.n_effective,
    }];
    // DMLMC's *average* step: each level weighted by its refresh rate.
    println!(
        "{:>10} {:>14} {:>14} {:>18}",
        "P", "naive", "mlmc", "dmlmc (avg step)"
    );
    for p in [1usize, 4, 16, 64, 256, 1024, 1 << 14] {
        let m = PramMachine::new(p, model);
        let naive = m.step_makespan(&naive_jobs);
        let mlmc = m.step_makespan(&mlmc_jobs);
        // average DMLMC step makespan over one full period 2^{d lmax}
        let sched = dmlmc::coordinator::DelayedSchedule::new(cfg.problem.lmax, cfg.mlmc.d);
        let horizon = sched.period(cfg.problem.lmax) * 2;
        let mut total = 0.0;
        for t in 0..horizon {
            let jobs: Vec<LevelJob> = sched
                .levels_due(t)
                .into_iter()
                .map(|l| LevelJob { level: l, n_samples: alloc.n(l) })
                .collect();
            total += m.step_makespan(&jobs);
        }
        let dmlmc_avg = total / horizon as f64;
        println!("{p:>10} {naive:>14.0} {mlmc:>14.0} {dmlmc_avg:>18.1}");
    }
    println!(
        "\nreading: with few processors all methods are work-bound (MLMC ≈ DMLMC \
         win on work); past the saturation point naive/MLMC hit the 2^(c·lmax) \
         depth floor while delayed MLMC keeps scaling — the paper's headline."
    );
    Ok(())
}
