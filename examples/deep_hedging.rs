//! End-to-end driver (DESIGN.md §3, Figure 2): train the deep-hedging
//! model with all three methods — naive SGD, standard MLMC, delayed MLMC —
//! over multiple seeds, through the full three-layer stack (rust
//! coordinator -> PJRT -> AOT-compiled JAX/Pallas HLO), and report the
//! learning curves against both complexity axes plus the headline
//! comparison the paper makes.
//!
//! ```sh
//! make artifacts && cargo run --release --example deep_hedging
//! # smaller/faster:
//! cargo run --release --example deep_hedging -- --steps 100 --seeds 3
//! ```
//!
//! Writes per-run CSVs and aggregated curves under `out/deep_hedging/`
//! and prints the summary recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::Method;
use dmlmc::experiments::ExperimentRunner;
use dmlmc::metrics::writer::write_csv;
use dmlmc::util::cli::{Command, Opt};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("deep_hedging", "Figure-2 end-to-end driver")
        .opt(Opt::with_default("steps", "SGD steps per run", "300"))
        .opt(Opt::with_default("seeds", "seeds per method", "10"))
        .opt(Opt::with_default("n-effective", "effective batch N", "256"))
        .opt(Opt::with_default("lr", "learning rate", "0.05"))
        .opt(Opt::with_default("clip", "gradient-norm clip (0 = off)", "10"))
        .opt(Opt::with_default("out-dir", "output dir", "out/deep_hedging"))
        .opt(Opt::value("backend", "xla|native (default: auto)"));
    let (_, args) = match cmd.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default_paper();
    cfg.train.steps = args.parse_usize("steps")?.unwrap();
    cfg.train.n_seeds = args.parse_usize("seeds")?.unwrap();
    cfg.train.lr = args.parse_f64("lr")?.unwrap();
    cfg.train.clip_norm = args.parse_f64("clip")?.unwrap();
    cfg.train.eval_every = (cfg.train.steps / 15).max(1);
    cfg.mlmc.n_effective = args.parse_usize("n-effective")?.unwrap();
    cfg.runtime.out_dir = PathBuf::from(args.get_or("out-dir", "out/deep_hedging"));
    cfg.runtime.backend = match args.get("backend") {
        Some(b) => Backend::parse(b).expect("backend must be xla|native"),
        None if cfg.runtime.artifacts_dir.join("manifest.json").exists() => Backend::Xla,
        None => {
            eprintln!("artifacts not built; using native backend");
            Backend::Native
        }
    };

    eprintln!(
        "deep_hedging: {} steps x {} seeds x 3 methods, N = {}, backend = {}",
        cfg.train.steps,
        cfg.train.n_seeds,
        cfg.mlmc.n_effective,
        cfg.runtime.backend.name()
    );

    let t0 = std::time::Instant::now();
    let results = ExperimentRunner::new(&cfg).figure2()?;
    std::fs::create_dir_all(&cfg.runtime.out_dir)?;

    for (method, curves, agg) in &results {
        for curve in curves {
            write_csv(
                &cfg.runtime
                    .out_dir
                    .join(format!("curve_{}_seed{}.csv", method.name(), curve.seed)),
                curve,
            )?;
        }
        std::fs::write(
            cfg.runtime.out_dir.join(format!("figure2_{}.csv", method.name())),
            agg.to_csv(),
        )?;
    }

    // ----- Figure 2 style report ------------------------------------
    println!("\n=== Figure 2 (left): loss vs STANDARD complexity ===");
    print_summary(&results, |agg, i| agg.std_cost[i]);
    println!("\n=== Figure 2 (right): loss vs PARALLEL complexity ===");
    print_summary(&results, |agg, i| agg.par_cost[i]);

    // Headline: parallel cost to reach a common loss target.
    let target = results
        .iter()
        .map(|(_, _, agg)| *agg.loss_mean.last().unwrap())
        .fold(f64::MIN, f64::max)
        * 1.02; // the worst method's final loss (±2%)
    println!("\n=== parallel cost to reach loss <= {target:.4} ===");
    for (method, curves, _) in &results {
        let costs: Vec<f64> = curves
            .iter()
            .filter_map(|c| c.par_cost_to_reach(target))
            .collect();
        if costs.is_empty() {
            println!("  {:<8} (target not reached)", method.name());
        } else {
            let mean = costs.iter().sum::<f64>() / costs.len() as f64;
            println!(
                "  {:<8} {:>12.0} depth units  ({}/{} runs reached)",
                method.name(),
                mean,
                costs.len(),
                curves.len()
            );
        }
    }
    let mlmc_final = results
        .iter()
        .find(|(m, _, _)| *m == Method::Mlmc)
        .map(|(_, _, a)| *a.par_cost.last().unwrap())
        .unwrap();
    let dmlmc_final = results
        .iter()
        .find(|(m, _, _)| *m == Method::Dmlmc)
        .map(|(_, _, a)| *a.par_cost.last().unwrap())
        .unwrap();
    println!(
        "\nDMLMC parallel-complexity advantage over MLMC at equal steps: {:.1}x",
        mlmc_final / dmlmc_final
    );
    eprintln!("total wall time: {:.1?}", t0.elapsed());
    eprintln!("wrote CSVs to {}", cfg.runtime.out_dir.display());
    Ok(())
}

type MethodResult =
    (Method, Vec<dmlmc::metrics::LearningCurve>, dmlmc::metrics::aggregate::AggregatedCurve);

fn print_summary(
    results: &[MethodResult],
    cost: impl Fn(&dmlmc::metrics::aggregate::AggregatedCurve, usize) -> f64,
) {
    println!(
        "{:<8} {:>8} {:>14} {:>12} {:>10}",
        "method", "step", "cost", "loss mean", "loss std"
    );
    for (method, _, agg) in results {
        let n = agg.steps.len();
        for i in [0, n / 2, n - 1] {
            println!(
                "{:<8} {:>8} {:>14.0} {:>12.5} {:>10.5}",
                method.name(),
                agg.steps[i],
                cost(agg, i),
                agg.loss_mean[i],
                agg.loss_std[i]
            );
        }
    }
}
