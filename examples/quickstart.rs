//! Quickstart: train a deep-hedging model with the delayed-MLMC gradient
//! estimator (Algorithm 1 of the paper) and print the learning curve.
//!
//! Uses the AOT artifacts if present (`make artifacts`), otherwise falls
//! back to the pure-rust engine so the example always runs:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.train.steps = 60;
    cfg.train.eval_every = 10;
    cfg.mlmc.n_effective = 128;
    cfg.runtime.backend = if cfg.runtime.artifacts_dir.join("manifest.json").exists() {
        Backend::Xla
    } else {
        eprintln!("artifacts not built; using the native engine backend");
        Backend::Native
    };

    println!(
        "deep hedging, delayed MLMC (d = {}), backend = {}, N = {}",
        cfg.mlmc.d,
        cfg.runtime.backend.name(),
        cfg.mlmc.n_effective
    );

    let mut trainer = Trainer::from_config(&cfg, Method::Dmlmc, 0)?;
    let curve = trainer.run()?;

    println!("\n{:>6} {:>12} {:>14} {:>12}", "step", "loss", "std cost", "par cost");
    for p in &curve.points {
        println!(
            "{:>6} {:>12.5} {:>14.0} {:>12.0}",
            p.step, p.loss, p.std_cost, p.par_cost
        );
    }

    let total = trainer.cumulative_cost();
    println!(
        "\nfinal loss {:.5}; total work {:.0} units, total depth {:.0} units",
        curve.final_loss().unwrap(),
        total.work,
        total.depth
    );
    println!(
        "(standard MLMC would have spent depth {:.0} on the same {} steps)",
        cfg.train.steps as f64 * 2f64.powi(cfg.problem.lmax as i32),
        cfg.train.steps
    );
    Ok(())
}
