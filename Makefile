# Build driver for the two-language stack.
#
#   make artifacts   one-time AOT lowering (JAX -> HLO text + manifest)
#   make build       release build of the rust crate (native engine works
#                    without artifacts; PJRT needs `--features xla`)
#   make test        tier-1 suite (`cargo test -q`); XLA integration tests
#                    self-skip while artifacts are missing
#
# Python never runs on the training hot path — after `make artifacts` the
# `repro` binary and all examples/benches are self-contained.

ARTIFACTS_DIR := rust/artifacts

.PHONY: artifacts build test fmt clippy bench bench-parallel bench-exec \
	bench-fleet bench-hotpath bench-adaptive trace serve-smoke clean

artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

bench:
	cd rust && cargo bench --bench hotpath

# Measured pool makespan vs PRAM prediction over P x method; emits
# rust/BENCH_parallel.json (see `repro parallel-sweep --help`).
bench-parallel:
	cd rust && cargo run --release --bin repro -- parallel-sweep --quiet

# Resident vs scoped (spawn-per-dispatch) pool overhead on light
# level-0-only dispatches (see `repro exec-bench --help`).
bench-exec:
	cd rust && cargo run --release --bin repro -- exec-bench

# Serving-fleet throughput: one resident pool multiplexing N trainers,
# swept over fleet size x workers; emits rust/BENCH_fleet.json (see
# `repro fleet-sweep --help`).
bench-fleet:
	cd rust && cargo run --release --bin repro -- fleet-sweep --quiet

# Scalar vs lane-blocked (SIMD) kernel throughput per scenario; emits
# rust/BENCH_hotpath.json with paths_per_sec and speedup per cell (see
# `repro hotpath-bench --help`).
bench-hotpath:
	cd rust && cargo run --release --bin repro -- hotpath-bench --quiet

# Fixed vs adaptive allocation ablation: the same DMLMC training with
# the offline-theory constants and with the telemetry-driven policy,
# compared on wall clock to a shared target loss and measured parallel
# cost per step; emits rust/BENCH_adaptive.json (see
# `repro adaptive-sweep --help`).
bench-adaptive:
	cd rust && cargo run --release --bin repro -- adaptive-sweep \
		--config ../configs/adaptive.toml --quiet

# Overhead-bounded tracing bench: the same DMLMC training traced and
# untraced (bit-identical parameters asserted), exporting trace.json
# (Perfetto-loadable) + metrics.prom and emitting rust/BENCH_obs.json
# (see `repro trace --help`).
trace:
	cd rust && cargo run --release --bin repro -- trace --quiet

# Self-terminating serve smoke: a resident traced fleet behind the live
# HTTP scrape surface (GET /metrics | /status | /sessions/<id>), here
# bounded by --max-ticks so it exits on its own once the sessions drain
# (the daemon form is `repro serve --config configs/serve.toml`, SIGINT
# to stop; see `repro serve --help`).
serve-smoke:
	cd rust && cargo run --release --bin repro -- serve \
		--config ../configs/serve.toml --port 0 --steps 16 --max-ticks 64 --quiet

clean:
	rm -rf $(ARTIFACTS_DIR)
	-cd rust && cargo clean
